"""Architecture registry: ``get_config(arch)`` / ``get_smoke(arch)``.

Ten assigned LM architectures + the paper's own CNN zoo (repro.models.zoo).
"""
from __future__ import annotations

import importlib

from .base import SHAPE_GRID, ModelCfg, MoECfg, ShapeCfg, SSMCfg, applicable_shapes

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "minitron-4b": "minitron_4b",
    "llama3.2-1b": "llama3_2_1b",
    "internlm2-1.8b": "internlm2_1_8b",
}

ARCHS = tuple(_ARCH_MODULES)


def _module(arch: str):
    try:
        mod = _ARCH_MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str) -> ModelCfg:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelCfg:
    return _module(arch).SMOKE


__all__ = ["ARCHS", "SHAPE_GRID", "ModelCfg", "MoECfg", "SSMCfg", "ShapeCfg",
           "applicable_shapes", "get_config", "get_smoke"]
