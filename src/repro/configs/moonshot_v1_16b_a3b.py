"""Moonlight-16B-A3B (kimi/moonshot) [hf:moonshotai/Moonlight-16B-A3B].

48L, 64 experts top-6, every layer MoE, huge vocab (163840).
"""
from .base import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=0,
    vocab=163840,
    period=1,
    attn_every=(0,),
    moe_every=(0,),
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408),
)

SMOKE = ModelCfg(
    name="moonshot-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=0,
    vocab=256,
    period=1,
    attn_every=(0,),
    moe_every=(0,),
    moe=MoECfg(n_experts=8, top_k=3, d_ff_expert=64),
)
