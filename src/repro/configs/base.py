"""Model / shape / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelCfg`` built from
repeating *periods* of heterogeneous sublayers (attn / ssm, dense-FFN /
MoE-FFN), so a 72-layer hybrid compiles as a 9-iteration ``lax.scan`` over
stacked period parameters — HLO size stays O(period), not O(depth).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length for the train/prefill scan

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    n_layers: int          # decoder layers (total sublayer count)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int              # dense FFN hidden (0 = no dense FFN, e.g. mamba2)
    vocab: int
    # --- layer pattern -----------------------------------------------------
    period: int = 1                       # layers per scanned period
    attn_every: tuple[int, ...] = (0,)    # in-period indices with attention
    ssm_every: tuple[int, ...] = ()       # in-period indices with SSM mixer
    moe_every: tuple[int, ...] = ()       # in-period indices with MoE FFN
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    # --- encoder (enc-dec archs only) --------------------------------------
    n_enc_layers: int = 0
    enc_frontend: Literal["none", "stub_audio", "stub_patch"] = "none"
    # --- flavor -------------------------------------------------------------
    rope_theta: float = 1e4
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl M-RoPE
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # sub-quadratic sequence mixing available (SSM / hybrid)?
    # (full-attention archs skip the long_500k cell — DESIGN.md)
    # derived below.

    def __post_init__(self) -> None:
        if self.n_layers % self.period:
            raise ValueError(f"{self.name}: n_layers % period != 0")
        for idx_set in (self.attn_every, self.ssm_every, self.moe_every):
            if any(i >= self.period for i in idx_set):
                raise ValueError(f"{self.name}: pattern index out of period")
        if set(self.attn_every) & set(self.ssm_every):
            raise ValueError(f"{self.name}: a layer cannot be attn and ssm")
        if len(set(self.attn_every) | set(self.ssm_every)) != self.period:
            raise ValueError(f"{self.name}: every layer needs a mixer")

    # --- derived -------------------------------------------------------------
    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def sub_quadratic(self) -> bool:
        return len(self.ssm_every) > 0

    @property
    def attention_free(self) -> bool:
        return len(self.attn_every) == 0

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up for 16-way tensor-parallel sharding."""
        return -(-self.vocab // 16) * 16

    def layer_kind(self, l: int) -> tuple[str, str]:
        """(mixer, ffn) for absolute layer index l."""
        i = l % self.period
        mixer = "attn" if i in self.attn_every else "ssm"
        ffn = "moe" if i in self.moe_every else ("dense" if self.d_ff else "none")
        return mixer, ffn

    # --- parameter counts (for roofline MODEL_FLOPS and HBM budgeting) ------
    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params_per_token)."""
        d = self.d_model
        total = active = 0
        emb = self.vocab_padded * d
        total += emb * (1 if self.tie_embeddings else 2)
        active += emb * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
            if self.qkv_bias:
                qkv += (self.n_heads + 2 * self.n_kv_heads) * self.d_head
            return qkv + self.n_heads * self.d_head * d

        def ssm_params() -> int:
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            gn = self.ssm.n_groups * self.ssm.d_state
            nh = self.ssm.n_ssm_heads(d)
            proj_in = d * (2 * di + 2 * gn + nh)
            conv = (di + 2 * gn) * self.ssm.d_conv
            extra = nh * 3  # A_log, D, dt_bias
            return proj_in + conv + extra + di * d

        def dense_ffn() -> int:
            return 3 * d * self.d_ff

        def moe_ffn() -> tuple[int, int]:
            assert self.moe is not None
            per_expert = 3 * d * self.moe.d_ff_expert
            router = d * self.moe.n_experts
            tot = per_expert * self.moe.n_experts + router
            act = per_expert * self.moe.top_k + router
            return tot, act

        n_all_layers = self.n_layers + self.n_enc_layers
        for l in range(self.n_layers):
            mixer, ffn = self.layer_kind(l)
            p = attn_params() if mixer == "attn" else ssm_params()
            total += p
            active += p
            if ffn == "dense":
                total += dense_ffn()
                active += dense_ffn()
            elif ffn == "moe":
                t, a = moe_ffn()
                total += t
                active += a
        for _ in range(self.n_enc_layers):  # encoder: attn + dense ffn + cross
            p = attn_params() + dense_ffn()
            total += p
            active += p
        if self.is_enc_dec:  # decoder cross-attention per decoder layer
            for _ in range(self.n_layers):
                total += attn_params()
                active += attn_params()
        del n_all_layers
        return total, active


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_GRID: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelCfg) -> list[str]:
    """The spec's skip rules: long_500k only for sub-quadratic archs."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names
