"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf].

28L dense decoder with M-RoPE (temporal/height/width sections 16/24/24 over
d_head=128 -> rotary half 64 = 16+24+24) and QKV bias. The vision patch
frontend is a STUB per the assignment: ``input_specs`` provides precomputed
patch embeddings + 3D position ids.
"""
from .base import ModelCfg

CONFIG = ModelCfg(
    name="qwen2-vl-2b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ModelCfg(
    name="qwen2vl-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    mrope_sections=(2, 3, 3),
    qkv_bias=True,
)
