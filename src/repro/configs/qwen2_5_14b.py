"""Qwen2.5-14B [hf:Qwen/Qwen2.5-*]: dense GQA decoder with QKV bias."""
from .base import ModelCfg

CONFIG = ModelCfg(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ModelCfg(
    name="qwen25-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
)
