"""Jamba-1.5-Large (398B total / ~94B active) [arXiv:2403.19887; hf].

72 layers, Mamba:attention 7:1 interleave (attention at in-period index 4,
one per 8-layer period), MoE (16 experts, top-2) on every other layer.
"""
from .base import ModelCfg, MoECfg, SSMCfg

CONFIG = ModelCfg(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    period=8,
    attn_every=(4,),
    ssm_every=(0, 1, 2, 3, 5, 6, 7),
    moe_every=(1, 3, 5, 7),
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64),
    rope_theta=1e4,
)

SMOKE = ModelCfg(
    name="jamba-smoke",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    period=8,
    attn_every=(4,),
    ssm_every=(0, 1, 2, 3, 5, 6, 7),
    moe_every=(1, 3, 5, 7),
    moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=128),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
)
