"""Mamba2-1.3B [arXiv:2405.21060]: attention-free SSD stack.

48L, d_model 2048 (d_inner 4096, 64 SSD heads of dim 64), d_state 128,
vocab 50280 (padded to 50288 for 16-way TP).
"""
from .base import ModelCfg, SSMCfg

CONFIG = ModelCfg(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab=50280,
    period=1,
    attn_every=(),
    ssm_every=(0,),
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64),
    tie_embeddings=True,
)

SMOKE = ModelCfg(
    name="mamba2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab=256,
    period=1,
    attn_every=(),
    ssm_every=(0,),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    tie_embeddings=True,
)
