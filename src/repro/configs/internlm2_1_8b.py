"""InternLM2-1.8B [arXiv:2403.17297; hf]."""
from .base import ModelCfg

CONFIG = ModelCfg(
    name="internlm2-1.8b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92544,
)

SMOKE = ModelCfg(
    name="internlm2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
)
