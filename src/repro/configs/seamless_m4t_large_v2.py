"""SeamlessM4T-Large v2 text backbone [arXiv:2308.11596; hf].

Encoder-decoder: 24 encoder + 24 decoder layers, d_model 1024, 16 heads
(MHA: kv=16), d_ff 8192, vocab 256206 (padded to 256208 for 16-way TP).
The speech/audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model).
"""
from .base import ModelCfg

CONFIG = ModelCfg(
    name="seamless-m4t-large-v2",
    n_layers=24,
    n_enc_layers=24,
    enc_frontend="stub_audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256206,
)

SMOKE = ModelCfg(
    name="seamless-smoke",
    n_layers=2,
    n_enc_layers=2,
    enc_frontend="stub_audio",
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=256,
)
