"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]."""
from .base import ModelCfg

CONFIG = ModelCfg(
    name="llama3.2-1b",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,
    d_ff=8192,
    vocab=128256,
    rope_theta=5e5,
    tie_embeddings=True,
)

SMOKE = ModelCfg(
    name="llama32-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
)
