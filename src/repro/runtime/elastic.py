"""Fault tolerance at 1000+ nodes: heartbeats, failure detection, elastic
remesh planning and straggler mitigation.

The control loop (launch/train.py) runs:
    monitor.beat(worker, now) on every incoming heartbeat
    plan = planner.plan(monitor.alive(now))
    if plan.remesh: restore from last checkpoint on the surviving slab,
                    rebuild the mesh with the shrunken data axis, recompile.

Remesh policy: model/TP axes are sacred (a missing TP shard makes the whole
slice unusable); failures remove whole data-parallel *slices*, and the
surviving slice count is rounded down to a power of two so the global batch
keeps dividing evenly (batch is rescaled or grad-accumulated to preserve
optimizer dynamics — plan.grad_accum reports the factor).

Straggler mitigation follows the paper's STAP logic: a slice whose step
EWMA exceeds k x median is flagged; the planner first reroutes its
microbatches to a replica (STAP stage replication) and evicts it only on
persistent lag.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Sequence


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, now: float) -> None:
        self._last[worker] = now

    def alive(self, now: float) -> list[int]:
        return sorted(w for w, t in self._last.items()
                      if now - t <= self.timeout_s)

    def dead(self, now: float) -> list[int]:
        return sorted(w for w, t in self._last.items()
                      if now - t > self.timeout_s)


@dataclasses.dataclass
class StragglerDetector:
    """Per-slice step-time EWMA; flag > k x median of peers."""

    alpha: float = 0.2
    k: float = 1.5
    _ewma: dict = dataclasses.field(default_factory=dict)

    def record(self, slice_id: int, step_time_s: float) -> None:
        prev = self._ewma.get(slice_id)
        self._ewma[slice_id] = (step_time_s if prev is None
                                else self.alpha * step_time_s
                                + (1 - self.alpha) * prev)

    def stragglers(self) -> list[int]:
        if len(self._ewma) < 2:
            return []
        med = sorted(self._ewma.values())[len(self._ewma) // 2]
        return sorted(s for s, t in self._ewma.items() if t > self.k * med)


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    remesh: bool
    data_slices: int       # new data-axis extent (power of two)
    dropped_slices: tuple[int, ...]
    grad_accum: int        # microbatch accumulation to preserve global batch

    @property
    def survives(self) -> bool:
        return self.data_slices >= 1


@dataclasses.dataclass
class ElasticPlanner:
    total_slices: int            # data-parallel slices (e.g. 16 or 32)
    chips_per_slice: int = 16    # the TP/model extent

    def plan(self, alive_slices: Sequence[int]) -> RemeshPlan:
        alive = sorted(set(alive_slices))
        n = len(alive)
        if n == self.total_slices:
            return RemeshPlan(False, self.total_slices, (), 1)
        if n == 0:
            return RemeshPlan(True, 0, tuple(range(self.total_slices)), 1)
        keep = 2 ** int(math.floor(math.log2(n)))
        dropped = tuple(s for s in range(self.total_slices)
                        if s not in set(alive[:keep]))
        grad_accum = max(1, self.total_slices // keep)
        return RemeshPlan(True, keep, dropped, grad_accum)
