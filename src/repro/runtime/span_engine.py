"""Compiled span execution engine: route a DP partition to real kernels.

Takes a :class:`~repro.core.partition.PartitionResult` (or a raw boundary
list) and executes the net span-by-span on a batch of images. Engines live
in the deployment registry (``repro.occam.registry``); this module
registers the four built-in ones at import:

* ``pallas`` — the generated N-layer fused-span kernel
  (``repro.kernels.fused_span``): conv/pool spans, any per-layer k /
  stride / same-padding, residual edges (in-span adds, sources crossing
  in from DRAM, spills of partition-crossing sources), multi-row output
  tiles (``out_rows``), batch in the leading grid dimension so filters
  stay VMEM-resident across images (paper Eqn. 6).
* ``scan`` — the jitted row-streaming twin
  (``repro.models.cnn._span_scan_jit``): same schedule and row math as
  the kernel, as a plain ``lax.fori_loop`` (forced-backend / A-B
  reference).
* ``oracle`` — layer-by-layer execution for oversized single layers (the
  DP's lower-bound spans, which by definition exceed on-chip capacity) or
  spans whose schedule fails validation.
* ``interpreted`` — the Python RowRing loop (the executable
  specification); never auto-selected, available as a forced backend.

``plan_routes`` asks ``registry.route_span`` per span — adding a backend
elsewhere (a real-TPU kernel, a continuous-stream body) is a
``register_engine`` call, not an edit here.

Off-chip traffic is accounted per span boundary exactly as
``repro.models.cnn.occam_forward`` does (model == machine: totals equal
``predicted_transfers`` x batch), regardless of which engine ran the span.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import closure
from repro.core.graph import NetSpec
from repro.core.partition import PartitionResult
from repro.kernels.fused_span import ops as span_ops
from repro.models import cnn
from repro.occam import registry

ROUTE_PALLAS = "pallas"
ROUTE_SCAN = "scan"
ROUTE_ORACLE = "oracle"
ROUTE_INTERPRETED = "interpreted"


@dataclasses.dataclass(frozen=True)
class SpanRoute:
    start: int
    end: int
    route: str
    reason: str


def _boundaries_of(partition: PartitionResult | Sequence[int],
                   net: NetSpec) -> list[int]:
    if isinstance(partition, PartitionResult):
        return list(partition.boundaries)
    return list(partition)


def plan_routes(net: NetSpec,
                partition: PartitionResult | Sequence[int], *,
                backend: str = registry.AUTO, out_rows: int = 1,
                dtype: str | None = None) -> tuple[SpanRoute, ...]:
    """Decide per-span engine. Pure function of the net + partition.

    ``backend``: ``"auto"`` (priority dispatch over the registry) or a
    registered engine name to force every span onto it (BackendError if
    any span is ineligible).
    ``out_rows``: requested output tile height (rows per step), clamped
    per span to its output height (a deep net's tail maps are short);
    engines whose schedule cannot retain the closure at that height
    reject.
    ``dtype``: activation dtype name, when known at planning time.
    """
    boundaries = _boundaries_of(partition, net)
    cuts = [0] + boundaries + [net.n_layers]
    fits = {(sp.start, sp.end): sp.fits for sp in partition.spans} \
        if isinstance(partition, PartitionResult) else {}
    routes = []
    for a, b in zip(cuts, cuts[1:]):
        t = max(1, min(out_rows, net.map_shape(b)[0]))
        ctx = registry.RouteContext(fits=fits.get((a, b), True),
                                    out_rows=t, dtype=dtype)
        name, reason = registry.route_span(net, a, b, ctx, backend=backend)
        routes.append(SpanRoute(a, b, name, reason))
    return tuple(routes)


def execute_partition(params: list[dict], xs: jax.Array, net: NetSpec,
                      partition: PartitionResult | Sequence[int], *,
                      counter: cnn.TrafficCounter | None = None,
                      interpret: bool | None = None,
                      routes: tuple[SpanRoute, ...] | None = None,
                      out_rows: int = 1, policy=None) -> jax.Array:
    """Execute ``net`` on ``xs`` ((B, H, W, C) or (H, W, C)) span-by-span.

    ``counter`` accumulates off-chip element transfers (x batch), matching
    ``cnn.predicted_transfers(net, boundaries) * batch``; under a policy
    the byte twins scale by the boundary width.
    ``out_rows``: output tile height per step (Eqn. 6 amortization).
    ``policy``: an ``occam.quant.DtypePolicy`` — every map that crosses a
    span boundary (input, span outputs, spills, residual sources) makes
    the round trip through the policy's boundary dtype before the next
    span reads it, and weights through the weight dtype, so the
    single-device result is bit-identical to a pipeline placement doing
    real quantized transport. Dequant happens at span entry: span bodies
    compute in ``policy.compute`` (a float dtype), which is why int8
    boundaries still route onto the float-only engines.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = xs.ndim == 3
    if squeeze:
        xs = xs[None]
    batch = xs.shape[0]
    if policy is not None and policy.is_default:
        policy = None
    if policy is None:
        boundary = lambda arr: arr  # noqa: E731
        bpe = 4.0
    else:
        from repro.occam.quant import casting

        params = casting.quantize_params(params, policy)
        boundary = functools.partial(casting.fake_quant,
                                     dtype=policy.boundary,
                                     scale=policy.scale)
        bpe = policy.boundary_bytes
    boundaries = _boundaries_of(partition, net)
    routes = routes or plan_routes(
        net, partition, out_rows=out_rows,
        dtype=policy.compute if policy is not None else str(xs.dtype))
    crossing = [(s, t) for (s, t) in net.residual_edges
                if any(s < p < t for p in boundaries)]
    spill_sources = {s for (s, _t) in crossing}
    stored: dict[int, jax.Array] = {0: boundary(xs)}
    for route in routes:
        a, b = route.start, route.end
        cnn.count_span_reads(counter, net, a, b, batch, bytes_per_elem=bpe)
        spill = tuple(sorted(m for m in spill_sources if a < m < b))
        engine = registry.get_engine(route.route)
        t = max(1, min(out_rows, net.map_shape(b)[0]))  # per-span clamp
        out, spilled = engine.run(params, net, a, b, stored, spill,
                                  interpret=interpret, out_rows=t)
        cnn.count_span_writes(counter, net, b, spilled, batch,
                              bytes_per_elem=bpe)
        stored[b] = boundary(out)
        stored.update({m: boundary(v) for m, v in spilled.items()})
    y = stored[net.n_layers]
    return y[0] if squeeze else y


# --------------------------------------------------------------------------
# Built-in engines: eligibility checks
# --------------------------------------------------------------------------

def _oversized(net: NetSpec, a: int, b: int,
               ctx: registry.RouteContext) -> bool:
    """The DP's lower-bound case: a single layer that exceeds capacity."""
    return not ctx.fits and b - a == 1


# Activation dtypes the generated kernel's row math supports (conv_row
# accumulates in float32; integer activations would silently change ReLU
# and pooling semantics). Declared on the EngineSpec so ``route_span``
# gates on it before ``accepts`` runs — int8 *boundaries* still route
# here because a DtypePolicy's ``compute`` dtype (what the span body
# sees after dequant-at-entry) is always a float.
_PALLAS_DTYPES = ("float32", "bfloat16", "float16")


def _tile_shape_reason(net: NetSpec, a: int, b: int,
                       out_rows: int) -> str | None:
    """Named tile-shape disqualifier for SPAN(a, b) at ``out_rows``, or
    None when the requested tile height is representable."""
    if out_rows < 1:
        return f"tile shape: out_rows={out_rows} (must be >= 1)"
    out_h = net.map_shape(b)[0]
    if out_rows > out_h:
        return (f"tile shape: out_rows={out_rows} exceeds span output "
                f"height {out_h}")
    return None


def _pallas_accepts(net: NetSpec, a: int, b: int,
                    ctx: registry.RouteContext) -> tuple[bool, str]:
    """Kernel eligibility. Rejections name the specific disqualifier —
    the BackendError a forced ``backend="pallas"`` raises carries it."""
    if _oversized(net, a, b, ctx):
        return False, "oversized single layer (lower bound)"
    bad_tile = _tile_shape_reason(net, a, b, ctx.out_rows)
    if bad_tile:
        return False, bad_tile
    # Residual edges are first-class now: in-span targets add from the
    # closure rings (or DRAM operands for sources crossing in), interior
    # sources of partition-crossing edges stream out as spills. The
    # schedule build proves every residual source is still ring-resident
    # when its target row needs it — a proof failure names the edge.
    touched = [(s, t) for (s, t) in net.residual_edges
               if a < t <= b or a < s < b]
    try:
        closure.span_schedule(net, a, b, out_rows=ctx.out_rows)
    except (AssertionError, RuntimeError) as e:
        kind = f"residual edges {touched}: " if touched else ""
        return False, (f"schedule rejected at out_rows={ctx.out_rows}: "
                       f"{kind}{e}")
    if touched:
        return True, f"fused span kernel (residual edges {touched})"
    return True, "fused span kernel"


def _scan_accepts(net: NetSpec, a: int, b: int,
                  ctx: registry.RouteContext) -> tuple[bool, str]:
    if _oversized(net, a, b, ctx):
        return False, "oversized single layer (lower bound)"
    bad_tile = _tile_shape_reason(net, a, b, ctx.out_rows)
    if bad_tile:
        return False, bad_tile
    touched = [(s, t) for (s, t) in net.residual_edges
               if a < t <= b or a < s < b]
    try:
        closure.span_schedule(net, a, b, out_rows=ctx.out_rows)
    except (AssertionError, RuntimeError) as e:
        return False, f"schedule rejected at out_rows={ctx.out_rows}: {e}"
    if touched:
        return True, f"residual edges {touched}"
    return True, "jitted row-streaming scan"


def _always_accepts(reason: str):
    def accepts(net: NetSpec, a: int, b: int,
                ctx: registry.RouteContext) -> tuple[bool, str]:
        if _oversized(net, a, b, ctx):
            return True, "oversized single layer (lower bound)"
        return True, reason
    return accepts


# --------------------------------------------------------------------------
# Built-in engines: span runners
# --------------------------------------------------------------------------

def _span_src_keys(net: NetSpec, a: int, b: int) -> tuple[int, ...]:
    """DRAM-resident residual sources crossing into SPAN(a, b)."""
    return tuple(sorted({s for (s, t) in net.residual_edges
                         if s < a < t <= b}))


def _run_pallas(params, net: NetSpec, a: int, b: int, stored, spill, *,
                interpret: bool, out_rows: int = 1):
    """The fused kernel on one span: residual sources crossing in ride as
    DRAM operands, partition-crossing interior sources spill as extra
    kernel outputs, ``out_rows`` output row-planes per grid step."""
    src_keys = _span_src_keys(net, a, b)
    out = span_ops.span_forward(stored[a], params[a:b], net, a, b,
                                interpret=interpret, out_rows=out_rows,
                                srcs={s: stored[s] for s in src_keys},
                                spill=spill)
    if spill:
        return out  # already (ys, {map -> spilled})
    return out, {}


def _run_scan(params, net: NetSpec, a: int, b: int, stored, spill, *,
              interpret: bool, out_rows: int = 1):
    """Batched jitted row-streaming of one span (vmap over images)."""
    src_keys = _span_src_keys(net, a, b)
    schedule = closure.span_schedule(net, a, b, spill=spill,
                                     out_rows=out_rows)
    fn = functools.partial(cnn._span_scan_jit, net=net, a=a, b=b,
                           schedule=schedule, spill=spill,
                           src_keys=src_keys)
    out, spills = jax.vmap(fn, in_axes=(None, 0, 0))(
        tuple(params[a:b]), stored[a],
        tuple(stored[s] for s in src_keys))
    return out, dict(zip(spill, spills))


def _run_oracle(params, net: NetSpec, a: int, b: int, stored, spill, *,
                interpret: bool, out_rows: int = 1):
    """Layer-by-layer batched execution of one span (+ residual adds)."""
    maps = {a: stored[a]}
    y = stored[a]
    for m in range(a + 1, b + 1):
        layer = net.layers[m - 1]
        if layer.kind == "conv":
            f = lambda im: cnn._conv_window(  # noqa: E731
                cnn._pad_rows_zero(im, layer), params[m - 1]["w"],
                params[m - 1]["b"], layer)
        else:
            f = lambda im: cnn._pool_window(  # noqa: E731
                cnn._pad_rows_neg(im, layer), layer)
        y = jax.vmap(f)(y)
        for (s, t) in net.residual_edges:
            if t != m:
                continue
            src = stored[s] if s < a else maps[s]
            y = y + jax.vmap(
                lambda sm, shape=y.shape[1:]: cnn._project_shortcut(
                    sm, *shape))(src)
        maps[m] = y
    return y, {m: maps[m] for m in spill}


def _run_interpreted(params, net: NetSpec, a: int, b: int, stored, spill, *,
                     interpret: bool, out_rows: int = 1):
    """The Python RowRing loop (executable specification), per image.

    ``out_rows`` is accepted for signature parity and ignored: the oracle
    and the RowRing specification execute whole maps / single rows, so
    tile height changes nothing about their results or their costs."""
    outs, spills = [], {m: [] for m in spill}
    for i in range(stored[a].shape[0]):
        sto_i = {k: v[i] for k, v in stored.items()}
        out, sp = cnn._stream_span(params, net, a, b, sto_i, set(spill))
        outs.append(out)
        for m in spill:
            spills[m].append(sp[m])
    return jnp.stack(outs), {m: jnp.stack(v) for m, v in spills.items()}


# --------------------------------------------------------------------------
# SPMD pipeline stage bodies (shard_map-traceable span cores)
# --------------------------------------------------------------------------

def _pallas_spmd_body(net: NetSpec, a: int, b: int, spill, src_keys, *,
                      out_rows: int = 1):
    """Stage-body builder for the pallas engine: the fused span kernel as
    a shard_map-traceable pipeline stage core.

    Interpret mode is decided once at build time exactly as
    ``execute_partition`` decides it (pure-Python kernel evaluation off
    TPU — it traces fine under shard_map; the compiled kernel on real
    TPUs). The schedule is built (and ring-retention validated) here, at
    pipeline build time, and baked into the jit cache key."""
    interpret = jax.default_backend() != "tpu"

    def body(span_params, x, srcs):
        out, spilled = span_ops.span_pallas_call(
            x, list(span_params), net, a, b, interpret=interpret,
            out_rows=out_rows, srcs=dict(zip(src_keys, srcs)), spill=spill)
        return out, spilled

    return body


def _scan_spmd_body(net: NetSpec, a: int, b: int, spill, src_keys, *,
                    out_rows: int = 1):
    """Stage-body builder for the scan engine: the same row-streaming math
    as ``_run_scan``, with the static span schedule precomputed once at
    pipeline build time."""
    schedule = closure.span_schedule(net, a, b, spill=spill,
                                     out_rows=out_rows)
    fn = functools.partial(cnn._span_scan_jit, net=net, a=a, b=b,
                           schedule=schedule, spill=spill,
                           src_keys=src_keys)

    def body(span_params, x, srcs):
        out, spills = jax.vmap(fn, in_axes=(None, 0, 0))(
            tuple(span_params), x, srcs)
        return out, dict(zip(spill, spills))

    return body


def _oracle_spmd_body(net: NetSpec, a: int, b: int, spill, src_keys, *,
                      out_rows: int = 1):
    """Stage-body builder for the oracle engine (lower-bound spans)."""
    def body(span_params, x, srcs):
        stored = {a: x, **dict(zip(src_keys, srcs))}
        full = [{}] * a + list(span_params)
        return _run_oracle(full, net, a, b, stored, spill, interpret=False)

    return body


# Auto-dispatch order: kernel > compiled scan > oracle. The interpreted
# specification never wins auto (the oracle accepts everything first) but
# is a valid forced backend. spmd_capable marks the engines whose bodies
# trace under shard_map: pallas/scan/oracle all register a make_spmd_body
# (the pallas body runs the fused kernel — interpret-mode off TPU, the
# compiled kernel on real TPUs — so kernel-routed spans drive pipeline
# stages directly, no scan substitution); only the interpreted Python
# loop cannot trace and stays off pipelines.
registry.register_engine(
    ROUTE_PALLAS, priority=10, accepts=_pallas_accepts, run=_run_pallas,
    spmd_capable=True, make_spmd_body=_pallas_spmd_body,
    dtypes=_PALLAS_DTYPES,
    description="generated N-layer fused-span Pallas kernel")
registry.register_engine(
    ROUTE_SCAN, priority=20, accepts=_scan_accepts, run=_run_scan,
    spmd_capable=True, make_spmd_body=_scan_spmd_body,
    dtypes=_PALLAS_DTYPES,
    description="jitted row-streaming scan (residual-capable)")
registry.register_engine(
    ROUTE_ORACLE, priority=30, accepts=_always_accepts(
        "layer-by-layer fallback"), run=_run_oracle,
    spmd_capable=True, make_spmd_body=_oracle_spmd_body,
    description="layer-by-layer oracle (lower-bound spans)")
registry.register_engine(
    ROUTE_INTERPRETED, priority=100, accepts=_always_accepts(
        "interpreted RowRing specification"), run=_run_interpreted,
    description="Python RowRing loop (executable specification)")


