"""Compiled span execution engine: route a DP partition to real kernels.

Takes a :class:`~repro.core.partition.PartitionResult` (or a raw boundary
list) and executes the net span-by-span on a batch of images, dispatching
each span to the fastest engine that can take it:

* ``pallas`` — the generated N-layer fused-span kernel
  (``repro.kernels.fused_span``): residual-free conv/pool spans, any
  per-layer k / stride / same-padding, batch in the leading grid dimension
  so filters stay VMEM-resident across images (paper Eqn. 6).
* ``scan`` — the jitted row-streaming fallback
  (``repro.models.cnn._span_scan_jit``): spans touched by residual edges
  (in-span adds, sources crossing in from DRAM, spills of
  partition-crossing sources).
* ``oracle`` — layer-by-layer execution for oversized single layers (the
  DP's lower-bound spans, which by definition exceed on-chip capacity) or
  spans whose schedule fails validation.

Off-chip traffic is accounted per span boundary exactly as
``repro.models.cnn.occam_forward`` does (model == machine: totals equal
``predicted_transfers`` x batch), regardless of which engine ran the span.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax

from repro.core import closure
from repro.core.graph import NetSpec
from repro.core.partition import PartitionResult
from repro.kernels.fused_span import ops as span_ops
from repro.models import cnn

ROUTE_PALLAS = "pallas"
ROUTE_SCAN = "scan"
ROUTE_ORACLE = "oracle"


@dataclasses.dataclass(frozen=True)
class SpanRoute:
    start: int
    end: int
    route: str
    reason: str


def _boundaries_of(partition: PartitionResult | Sequence[int],
                   net: NetSpec) -> list[int]:
    if isinstance(partition, PartitionResult):
        return list(partition.boundaries)
    return list(partition)


def plan_routes(net: NetSpec,
                partition: PartitionResult | Sequence[int]) -> tuple[SpanRoute, ...]:
    """Decide per-span engine. Pure function of the net + partition."""
    boundaries = _boundaries_of(partition, net)
    cuts = [0] + boundaries + [net.n_layers]
    fits = {(sp.start, sp.end): sp.fits for sp in partition.spans} \
        if isinstance(partition, PartitionResult) else {}
    routes = []
    for a, b in zip(cuts, cuts[1:]):
        if not fits.get((a, b), True) and b - a == 1:
            routes.append(SpanRoute(a, b, ROUTE_ORACLE,
                                    "oversized single layer (lower bound)"))
            continue
        # Disqualifying edges: a target inside the span (needs in-span adds)
        # or an interior source (needs ring reads / boundary spills). An
        # edge merely *straddling* the span (s <= a, t > b) costs it
        # nothing — the source is already in DRAM — so ResNet-style spans
        # between skip endpoints still take the kernel.
        touched = [(s, t) for (s, t) in net.residual_edges
                   if a < t <= b or a < s < b]
        if touched:
            routes.append(SpanRoute(a, b, ROUTE_SCAN,
                                    f"residual edges {touched}"))
            continue
        try:
            closure.span_schedule(net, a, b)
        except (AssertionError, RuntimeError) as e:
            routes.append(SpanRoute(a, b, ROUTE_ORACLE,
                                    f"schedule rejected: {e}"))
            continue
        routes.append(SpanRoute(a, b, ROUTE_PALLAS, "fused span kernel"))
    return tuple(routes)


def execute_partition(params: list[dict], xs: jax.Array, net: NetSpec,
                      partition: PartitionResult | Sequence[int], *,
                      counter: cnn.TrafficCounter | None = None,
                      interpret: bool | None = None,
                      routes: tuple[SpanRoute, ...] | None = None
                      ) -> jax.Array:
    """Execute ``net`` on ``xs`` ((B, H, W, C) or (H, W, C)) span-by-span.

    ``counter`` accumulates off-chip element transfers (x batch), matching
    ``cnn.predicted_transfers(net, boundaries) * batch``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = xs.ndim == 3
    if squeeze:
        xs = xs[None]
    batch = xs.shape[0]
    boundaries = _boundaries_of(partition, net)
    routes = routes or plan_routes(net, partition)
    crossing = [(s, t) for (s, t) in net.residual_edges
                if any(s < p < t for p in boundaries)]
    spill_sources = {s for (s, _t) in crossing}
    stored: dict[int, jax.Array] = {0: xs}
    for route in routes:
        a, b = route.start, route.end
        cnn.count_span_reads(counter, net, a, b, batch)
        spill = tuple(sorted(m for m in spill_sources if a < m < b))
        if route.route == ROUTE_PALLAS:
            if spill:  # plan_routes never produces this; reject rather than
                raise ValueError(  # silently running a different engine
                    f"span ({a}, {b}) routed to pallas but must spill "
                    f"{spill}; use the scan route")
            out = span_ops.span_forward(stored[a], params[a:b], net, a, b,
                                        interpret=interpret)
            spilled: dict[int, jax.Array] = {}
        elif route.route == ROUTE_ORACLE:
            out, spilled = _oracle_span(params, net, a, b, stored, spill)
        else:
            out, spilled = _scan_span(params, net, a, b, stored,
                                      spill_sources)
        cnn.count_span_writes(counter, net, b, spilled, batch)
        stored[b] = out
        stored.update(spilled)
    y = stored[net.n_layers]
    return y[0] if squeeze else y


def _scan_span(params, net: NetSpec, a: int, b: int, stored,
               spill_sources):
    """Batched jitted row-streaming of one span (vmap over images)."""
    spill = tuple(sorted(m for m in spill_sources if a < m < b))
    src_keys = tuple(sorted({s for (s, t) in net.residual_edges
                             if s < a < t <= b}))
    schedule = closure.span_schedule(net, a, b, spill=spill)
    fn = functools.partial(cnn._span_scan_jit, net=net, a=a, b=b,
                           schedule=schedule, spill=spill,
                           src_keys=src_keys)
    out, spills = jax.vmap(fn, in_axes=(None, 0, 0))(
        tuple(params[a:b]), stored[a],
        tuple(stored[s] for s in src_keys))
    return out, dict(zip(spill, spills))


def _oracle_span(params, net: NetSpec, a: int, b: int, stored, spill):
    """Layer-by-layer batched execution of one span (+ residual adds)."""
    maps = {a: stored[a]}
    y = stored[a]
    for m in range(a + 1, b + 1):
        layer = net.layers[m - 1]
        if layer.kind == "conv":
            f = lambda im: cnn._conv_window(  # noqa: E731
                cnn._pad_rows_zero(im, layer), params[m - 1]["w"],
                params[m - 1]["b"], layer)
        else:
            f = lambda im: cnn._pool_window(  # noqa: E731
                cnn._pad_rows_neg(im, layer), layer)
        y = jax.vmap(f)(y)
        for (s, t) in net.residual_edges:
            if t != m:
                continue
            src = stored[s] if s < a else maps[s]
            y = y + jax.vmap(
                lambda sm, shape=y.shape[1:]: cnn._project_shortcut(
                    sm, *shape))(src)
        maps[m] = y
    return y, {m: maps[m] for m in spill}
