"""Occam pipeline runtime: DP-optimal partitions as pipeline stages.

This is contribution C3+C4 made executable for transformers:

  1. ``plan_stages`` — run the paper's DP (repro.core.partition) over the
     layer chain with an HBM capacity model -> contiguous layer spans.
  2. ``plan_stap`` — stage latency model (FLOPs/chip-rate) -> replication
     counts for bottleneck stages (STAP; see repro.core.stap).
  3. ``pipeline_forward`` — an executable GPipe-style microbatch pipeline
     over a ``stage`` mesh axis using shard_map + ppermute: each stage
     holds only its span's weights (chip-residency: weights load once and
     stay — the paper's full cross-image filter reuse), microbatches
     stream through, boundary activations are the only inter-stage
     traffic (exactly the quantity the DP minimized).

The schedule runs S + M - 1 ticks for S stages x M microbatches. STAP
*staggering* (microbatch m -> replica m mod r_i) is executable too: pass a
``plan`` (or per-stage ``replicas``) and a (stage, replica) mesh and
``pipeline_forward`` delegates to the staggered round executor in
``repro.runtime.stap_pipeline`` (which also runs heterogeneous Occam span
stages; the discrete-event simulator in core.stap verifies the throughput
claims).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.sharding import shard_map_compat as _shard_map

from repro.core.partition import PartitionResult, partition_transformer
from repro.core.stap import StapPlan, plan_replication


@dataclasses.dataclass(frozen=True)
class StagePlan:
    partition: PartitionResult
    stage_spans: tuple[tuple[int, int], ...]
    stage_flops: tuple[float, ...]
    stap: StapPlan


def plan_stages(layer_weight_bytes: Sequence[float],
                layer_act_bytes: Sequence[float],
                layer_flops: Sequence[float],
                boundary_act_bytes: float,
                stage_capacity_bytes: float,
                chip_flops_per_s: float = 197e12,
                extra_chips: int = 0) -> StagePlan:
    """DP partition -> stages; STAP replication under a chip budget."""
    part = partition_transformer(layer_weight_bytes, layer_act_bytes,
                                 boundary_act_bytes, stage_capacity_bytes)
    spans = tuple((sp.start, sp.end) for sp in part.spans)
    flops = tuple(float(sum(layer_flops[a:b])) for a, b in spans)
    times = [f / chip_flops_per_s for f in flops]
    stap = plan_replication(times, max_chips=len(spans) + extra_chips)
    return StagePlan(part, spans, flops, stap)


def pipeline_forward(stage_fn: Callable, stage_params,
                     microbatches: jax.Array, mesh: Mesh,
                     axis: str = "stage",
                     plan: StapPlan | Sequence[int] | None = None
                     ) -> jax.Array:
    """Run M microbatches through S pipeline stages.

    stage_fn(stage_params_slice, x) -> y, same shape as x.
    stage_params: pytree with leading stage dim S on every leaf (stage s
        holds slice s — its Occam span's weights, resident for the whole
        stream).
    microbatches: (M, mb, ...) replicated input.
    plan: optional STAP replication — a :class:`StapPlan` or per-stage
        replica counts. Requires ``mesh`` to carry a second ("replica")
        axis of width max(replicas); microbatch m is staggered onto
        replica m mod r_i (paper §III-E) by the round executor in
        ``repro.runtime.stap_pipeline``.
    Returns (M, mb, ...) outputs (as produced by the last stage).
    """
    if plan is not None:
        from repro.runtime import stap_pipeline

        if not isinstance(plan, StapPlan):
            # synthesize a plan from bare replica counts; with unit stage
            # times the closed-form throughput min_i r_i/t_i is min(reps)
            reps = tuple(int(r) for r in plan)
            plan = StapPlan((1.0,) * len(reps), reps, float(min(reps)),
                            float(len(reps)), sum(reps))
        replica_axis = next(
            (a for a in mesh.axis_names if a != axis),
            stap_pipeline.REPLICA_AXIS)
        return stap_pipeline.replicated_forward(
            stage_fn, stage_params, microbatches, mesh, plan,
            stage_axis=axis, replica_axis=replica_axis)

    s_stages = mesh.shape[axis]
    m = microbatches.shape[0]
    ticks = s_stages + m - 1

    def per_stage(params_local, mbs):
        # params_local leaves: (1, ...) — this stage's span weights.
        idx = lax.axis_index(axis)
        p_here = jax.tree.map(lambda l: l[0], params_local)
        buf = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros_like(mbs)

        def tick(carry, t):
            buf, outs = carry
            mb_id = t - idx
            active = jnp.logical_and(mb_id >= 0, mb_id < m)
            x_in = jnp.where(idx == 0,
                             mbs[jnp.clip(mb_id, 0, m - 1)], buf)
            y = stage_fn(p_here, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage deposits its finished microbatch
            is_last = idx == s_stages - 1
            outs = lax.cond(
                jnp.logical_and(active, is_last),
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_id, 0, m - 1), 0),
                lambda o: o, outs)
            # boundary activations move one hop down the chain (the only
            # inter-stage traffic — the DP's minimized quantity)
            nxt = lax.ppermute(
                y, axis, [(i, (i + 1) % s_stages) for i in range(s_stages)])
            return (nxt, outs), None

        (_, outs), _ = lax.scan(tick, (buf, outs0), jnp.arange(ticks))
        # finished microbatches stay on the last stage; the stage-sharded
        # output below is sliced, not psum-broadcast (a psum here would
        # move S x M x |act| zeros per call for one stage's payload)
        return outs

    out = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(axis),
        check_vma=False,
    )(stage_params, microbatches)
    return out[(s_stages - 1) * m:]
