"""Executable STAP runtime: staggered, replicated multi-chip span pipeline.

This is the paper's §III-E made runnable: a ``PartitionResult`` (the DP's
optimal spans) executes as a real SPMD pipeline over a ``(stage, replica)``
device mesh.

* Each stage holds *only its span's weights*, resident on its chips for the
  whole stream — Occam's full cross-image filter reuse (Eqn. 6) lifted to
  the multi-chip level.
* Mini-batch ``m`` is staggered onto replica ``m % r_i`` of stage ``i``
  following the :class:`~repro.core.stap.StapPlan`; the explicit lock-step
  tick schedule (ownership, fill/drain, routing) comes from
  :func:`~repro.core.stap.staggered_schedule`.
* Boundary activations (the span-boundary map plus every residual source
  crossing the cut — exactly the per-boundary quantity the DP minimized)
  move between stages by slot-level ``ppermute`` as the *only* inter-stage
  traffic: the replica that served a slot sends straight to the replica
  that will serve it next. There is no intra-stage collective until a
  single final ``psum`` assembles the last stage's outputs.
* Stage bodies dispatch through the engine registry
  (``EngineSpec.make_spmd_body``): kernel-routed spans run the fused
  Pallas span kernel directly under ``shard_map`` (interpret mode off
  TPU, the compiled kernel on real TPUs), scan-routed spans the jitted
  row-streaming twin, and oversized single layers the oracle, per
  ``repro.runtime.span_engine.plan_routes``.

Heterogeneous spans under one SPMD program: every boundary payload is
flattened to a fixed-width slot vector and every span's weights to a
fixed-width parameter vector, and the per-device program selects its span
body with ``lax.switch`` on the stage index — only the selected branch
executes at runtime, so a replica pays exactly its own span's FLOPs.

Input staging: the padded feed is *not* replicated to every device — it
is sharded over the stage axis (chip row i holds rounds [i*chunk,
(i+1)*chunk) of the stream) and an input conveyor of static stage-axis
``ppermute`` hops walks each round to stage 0 exactly when the schedule
consumes it, keeping per-chip input memory at O(stream/S).

Output staging is the same trick in reverse: no device banks the full
(rounds, width, slot) output buffer. The last stage injects each finished
round into an output conveyor that hops it along the cyclic stage ring to
its bank row, so every chip banks only ceil(rounds/S) rounds of output —
per-chip output memory O(stream/S), symmetric to the input side
(``collect_staged_outputs`` undoes the banking on the host).

Two executable forms share the span stages (whose bodies dispatch through
the engine registry — ``EngineSpec.make_spmd_body``):

* :class:`StapPipeline` — the fixed-round batch program: one ``lax.scan``
  over the whole staggered schedule, compiled per stream length.
* :class:`StapRing` — the serving form: ONE compiled fixed-shape SPMD
  tick (a ring of rounds, one per stage) iterated host-side, so a single
  lowering serves an unbounded stream of mixed submit sizes
  (``repro.occam.Deployment.serve`` builds sessions on it).

Runs on CPU CI via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(see ``tests/conftest.py``). Deployment entry: the staged API
(``repro.occam``: plan -> place -> compile -> run / serve); async
serving demo: ``examples/async_serve.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import NetSpec
from repro.core.partition import PartitionResult
from repro.core.stap import (StaggeredSchedule, StapPlan, plan_replication,
                             staggered_schedule, steady_schedule)
from repro.models import cnn
from repro.models.sharding import shard_map_compat as _shard_map
from repro.occam import registry
from repro.runtime import span_engine

STAGE_AXIS = "stage"
REPLICA_AXIS = "replica"
CHIP_AXIS = "chip"
PACKINGS = ("rect", "sum")


# --------------------------------------------------------------------------
# Static planning: boundary payloads and per-span stages
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """What crosses a partition cut: the boundary map plus every residual
    source with an edge straddling the cut. ``elems`` is therefore exactly
    the per-boundary quantity the DP charges (one direction)."""

    cut: int
    keys: tuple[int, ...]   # [cut, *sorted crossing residual sources]
    elems: int              # per-image payload elements


def payload_spec(net: NetSpec, cut: int) -> PayloadSpec:
    extras = sorted({s for (s, t) in net.residual_edges if s < cut < t})
    keys = (cut, *extras)
    return PayloadSpec(cut, keys, sum(net.map_elems(k) for k in keys))


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage: a span, its engine route, and its payloads."""

    route: span_engine.SpanRoute
    in_spec: PayloadSpec
    out_spec: PayloadSpec
    spill: tuple[int, ...]     # interior maps this span must materialize
    src_keys: tuple[int, ...]  # upstream sources consumed from the payload

    @property
    def span(self) -> tuple[int, int]:
        return self.route.start, self.route.end


def plan_span_stages(net: NetSpec,
                     partition: PartitionResult | Sequence[int],
                     routes: Sequence[span_engine.SpanRoute] | None = None
                     ) -> tuple[StageSpec, ...]:
    """Pure function of net + partition: spans -> pipeline stages.

    ``routes`` overrides the registry's auto dispatch (forced backends
    from ``Placement.compile``); it must cover exactly the partition's
    spans."""
    boundaries = span_engine._boundaries_of(partition, net)
    if routes is None:
        routes = span_engine.plan_routes(net, partition)
    crossing = [(s, t) for (s, t) in net.residual_edges
                if any(s < p < t for p in boundaries)]
    spill_sources = {s for (s, _t) in crossing}
    stages = []
    for route in routes:
        a, b = route.start, route.end
        stages.append(StageSpec(
            route=route,
            in_spec=payload_spec(net, a),
            out_spec=payload_spec(net, b),
            spill=tuple(sorted(m for m in spill_sources if a < m < b)),
            src_keys=tuple(sorted({s for (s, t) in net.residual_edges
                                   if s < a < t <= b})),
        ))
    return tuple(stages)


def model_stage_times(net: NetSpec, stages: Sequence[StageSpec]
                      ) -> tuple[float, ...]:
    """Per-stage latency model for planning when no measured times exist:
    conv MACs plus pool window ops (arbitrary units — only ratios matter
    to ``plan_replication``)."""
    times = []
    for st in stages:
        a, b = st.span
        ops = 0
        for layer in net.layers[a:b]:
            ops += layer.macs if layer.kind == "conv" \
                else layer.out_elems * layer.k * layer.k
        times.append(float(max(ops, 1)))
    return tuple(times)


def default_stap_plan(stage_times: Sequence[float], *,
                      max_chips: int | None = None,
                      max_replicas: int | None = None,
                      target_period: float | None = None,
                      mesh: Mesh | None = None,
                      devices: Sequence | None = None,
                      harmonize: bool = False) -> StapPlan:
    """The replication-planning defaults shared by :class:`StapPipeline`
    and ``repro.occam.Plan.place``: cap replicas at what the available
    (stage, replica) mesh can physically hold, and treat a replica-capable
    mesh with no stated budget as a budget of the whole mesh."""
    n_stages = len(stage_times)
    if max_replicas is None:
        # cap replication at what the (stage, replica) mesh can
        # physically hold, so natural chip budgets plan meshes
        # that actually exist
        if mesh is not None:
            max_replicas = mesh.shape.get(REPLICA_AXIS, 1)
        else:
            n_dev = len(devices) if devices is not None \
                else jax.device_count()
            max_replicas = max(1, n_dev // n_stages)
    if mesh is not None and max_chips is None and target_period is None:
        # a replica-capable mesh with no stated budget means "use
        # it": water-fill up to the devices the mesh holds (the
        # schedule must match the mesh shape exactly)
        max_chips = n_stages * max_replicas
    return plan_replication(stage_times, target_period=target_period,
                            max_chips=max_chips, max_replicas=max_replicas,
                            harmonize=harmonize)


def stap_mesh(n_stages: int, max_replicas: int,
              devices: Sequence | None = None) -> Mesh:
    """A (stage, replica) mesh over the first n_stages*max_replicas devices."""
    devs = list(devices if devices is not None else jax.devices())
    need = n_stages * max_replicas
    if len(devs) < need:
        raise ValueError(
            f"STAP mesh needs {n_stages}x{max_replicas} = {need} devices, "
            f"have {len(devs)} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax "
            f"import to emulate them on CPU)")
    arr = np.array(devs[:need]).reshape(n_stages, max_replicas)
    return Mesh(arr, (STAGE_AXIS, REPLICA_AXIS))


def packed_mesh(n_chips: int, devices: Sequence | None = None) -> Mesh:
    """A flat 1-D chip mesh over the first ``n_chips`` devices — the
    sum-of-replicas layout (§III-E): a 4-3-2 plan occupies 9 chips, not
    a rectangular 3x4 = 12."""
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < n_chips:
        raise ValueError(
            f"packed STAP mesh needs {n_chips} devices, have {len(devs)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_chips} before jax import to emulate them on CPU)")
    return Mesh(np.array(devs[:n_chips]), (CHIP_AXIS,))


# --------------------------------------------------------------------------
# Payload / parameter flattening (uniform SPMD buffers)
# --------------------------------------------------------------------------

def _pack(parts: dict[int, jax.Array], spec: PayloadSpec,
          width: int) -> jax.Array:
    """{map -> (mb, h, w, c)} -> (mb, width) zero-padded flat payload."""
    mb = parts[spec.keys[0]].shape[0]
    flat = jnp.concatenate([parts[k].reshape(mb, -1) for k in spec.keys],
                           axis=1)
    return jnp.pad(flat, ((0, 0), (0, width - flat.shape[1])))


def _unpack(payload: jax.Array, spec: PayloadSpec,
            net: NetSpec) -> dict[int, jax.Array]:
    parts, off = {}, 0
    for k in spec.keys:
        h, w, c = net.map_shape(k)
        n = h * w * c
        parts[k] = payload[:, off:off + n].reshape(-1, h, w, c)
        off += n
    return parts


def _span_param_elems(net: NetSpec, a: int, b: int) -> int:
    return sum(l.weight_elems + l.out_ch for l in net.layers[a:b]
               if l.kind == "conv")


def _flatten_span_params(params: Sequence[dict], net: NetSpec, a: int, b: int,
                         width: int) -> jax.Array:
    leaves = []
    for l in range(a, b):
        if net.layers[l].kind == "conv":
            leaves += [params[l]["w"].ravel(), params[l]["b"].ravel()]
    flat = jnp.concatenate(leaves) if leaves else jnp.zeros((0,), jnp.float32)
    return jnp.pad(flat, (0, width - flat.shape[0]))


def _unflatten_span_params(flat: jax.Array, net: NetSpec, a: int,
                           b: int) -> tuple[dict, ...]:
    out, off = [], 0
    for l in range(a, b):
        layer = net.layers[l]
        if layer.kind != "conv":
            out.append({})
            continue
        wsz = layer.weight_elems
        w = lax.slice_in_dim(flat, off, off + wsz).reshape(
            layer.k, layer.k, layer.in_ch, layer.out_ch)
        bv = lax.slice_in_dim(flat, off + wsz, off + wsz + layer.out_ch)
        out.append({"w": w, "b": bv})
        off += wsz + layer.out_ch
    return tuple(out)


# --------------------------------------------------------------------------
# The generic round executor (shared by heterogeneous spans and the
# homogeneous replicated transformer pipeline)
# --------------------------------------------------------------------------

def feed_chunk_rounds(n_rounds: int, n_stages: int) -> int:
    """Rounds of input feed resident per chip row: ceil(n_rounds / S)."""
    return -(-n_rounds // n_stages)


def out_chunk_rounds(n_rounds: int, n_stages: int) -> int:
    """Rounds of output banked per chip row — the same ceil(n_rounds / S)
    chunking as the input side (one rule, two conveyors)."""
    return feed_chunk_rounds(n_rounds, n_stages)


def output_bank_row(rg: int, n_rounds: int, n_stages: int) -> int:
    """Bank row of finished round ``rg`` under the output conveyor.

    Round rg finishes on the last stage row at tick rg + S - 1 and then
    hops cyclically (row S-1 -> 0 -> 1 -> ...) for h = (rounds-1-rg) mod S
    hops, landing on row (S-1+h) mod S. The reverse round-robin assignment
    is forced by finishing times: the *last* round finishes on the final
    tick and must bank with zero hops (row S-1), round rounds-2 gets at
    most one hop, and so on — so the conveyor drains within the schedule's
    existing ticks, with no extra drain ticks, while still spreading the
    rounds evenly (ceil(rounds/S) per row, round rg in bank slot rg // S).
    """
    return (n_rounds + n_stages - 2 - rg) % n_stages


def collect_staged_outputs(out: jax.Array, sched: StaggeredSchedule
                           ) -> jax.Array:
    """Undo the output conveyor's banking on the host: the staged
    (S * R * chunk, width, *slot) executable output -> (n_rounds, width,
    *slot) finished rounds in stream order, replica partials summed (each
    replica banked only its owned slots, zeros elsewhere — summed here
    instead of an inter-replica all-reduce of mostly-zero buffers)."""
    s, r, rounds = sched.n_stages, sched.max_replicas, sched.n_rounds
    chunk = out_chunk_rounds(rounds, s)
    arr = out.reshape((s, r, chunk) + out.shape[1:]).sum(axis=1)
    rg = np.arange(rounds)
    return arr[output_bank_row(rg, rounds, s), rg // s]


def stage_feed(feed: jax.Array, n_stages: int) -> jax.Array:
    """Pad a (n_rounds, ...) feed to (S * chunk, ...) for stage sharding.

    Chip row i initially holds rounds [i*chunk, (i+1)*chunk) — the input
    conveyor (see ``_round_executor``) walks them to stage 0 in time."""
    chunk = feed_chunk_rounds(feed.shape[0], n_stages)
    pad = n_stages * chunk - feed.shape[0]
    return jnp.pad(feed, ((0, pad),) + ((0, 0),) * (feed.ndim - 1))


def _round_executor(step, stage_params, feed: jax.Array, mesh: Mesh,
                    sched: StaggeredSchedule,
                    stage_axis: str = STAGE_AXIS,
                    replica_axis: str = REPLICA_AXIS) -> jax.Array:
    """Run the staggered lock-step schedule as one SPMD program.

    step(stage_idx, params_local, slot) -> slot', both of ``feed``'s
    trailing slot shape. ``feed``: (n_rounds, round_width, *slot) input —
    or its ``stage_feed`` padded form (S*chunk, round_width, *slot) when
    the caller already staged it onto devices. ``stage_params``: pytree
    with leading stage dim on every leaf. Returns the *staged* outputs —
    (S * R * chunk, round_width, *slot), each chip row banking
    ceil(n_rounds/S) finished rounds — which ``collect_staged_outputs``
    reassembles into (n_rounds, round_width, *slot) on the host.

    Input staging: the feed is *sharded over the stage axis* on its rounds
    dimension (chip row i holds rounds [i*chunk, (i+1)*chunk), replicated
    across the replica axis), never replicated whole — per-chip input
    memory is O(stream/S), not O(stream). Only stage 0 consumes rounds, so
    each tick every row forwards the round at its queue head one hop
    toward stage 0 (a static stage-axis ``ppermute`` — the input conveyor)
    and banks the round arriving from the row behind it in the freed slot.
    Row i's slot (t mod chunk) therefore holds round i*chunk + t at tick
    t, i.e. stage 0's head is exactly round t when it needs it.

    Output staging is the input conveyor in reverse: the last stage row
    injects each finished round into a one-slot transit buffer that hops
    along the *cyclic* stage ring (S-1 -> 0 -> 1 -> ...) once per tick;
    the row ``output_bank_row`` assigns to the round banks it when it
    arrives. Rounds enter transit one tick apart and move in lockstep, so
    at most one live round occupies any row's transit slot, and the
    reverse round-robin bank assignment drains the conveyor within the
    schedule's existing ticks (the last round banks with zero hops). No
    device ever materializes the full (rounds, width, *slot) buffer.

    Tick t: stage i serves round t - i; each replica runs only its owned
    *live* slots (``lax.cond`` — the skipped branch costs nothing at run
    time), then every slot's boundary payload ppermutes one hop down the
    pipe straight to the replica that will serve it next.
    """
    s_stages, r_max = sched.n_stages, sched.max_replicas
    got = (mesh.shape.get(stage_axis), mesh.shape.get(replica_axis))
    if got != (s_stages, r_max):
        # slot routing is computed over a (n_stages, max_replicas) grid; a
        # mismatched mesh would silently misroute every payload to zeros
        raise ValueError(
            f"mesh is {stage_axis}={got[0]}, {replica_axis}={got[1]} but "
            f"the schedule needs {s_stages}x{r_max} (replicas "
            f"{sched.replicas}); build it with stap_mesh({s_stages}, "
            f"{r_max})")
    width, rounds = sched.round_width, sched.n_rounds
    chunk = feed_chunk_rounds(rounds, s_stages)
    if feed.shape[0] == rounds:
        feed = stage_feed(feed, s_stages)
    if feed.shape[0] != s_stages * chunk:
        raise ValueError(f"feed has {feed.shape[0]} rounds; schedule needs "
                         f"{rounds} (staged: {s_stages * chunk})")
    out_chunk = out_chunk_rounds(rounds, s_stages)
    owner = jnp.asarray(np.array(sched.owner_table()))          # (S, R, W)
    live = jnp.asarray(np.array(sched.slot_live()))             # (G*W,)
    perms = [sched.slot_perm(w) for w in range(width)]
    conveyor = [(k, k - 1) for k in range(1, s_stages)]
    out_conveyor = [(k, (k + 1) % s_stages) for k in range(s_stages)]

    def per_device(params_local, queue0):
        i = lax.axis_index(stage_axis)
        j = lax.axis_index(replica_axis)
        p_here = jax.tree.map(lambda l: l[0], params_local)
        slot_shape = queue0.shape[2:]
        buf0 = jnp.zeros((width,) + slot_shape, queue0.dtype)
        outq0 = jnp.zeros((out_chunk, width) + slot_shape, queue0.dtype)
        transit0 = jnp.zeros((width,) + slot_shape, queue0.dtype)

        def tick(carry, t):
            buf, outq, transit, queue = carry
            rg = t - i
            active = jnp.logical_and(rg >= 0, rg < rounds)
            rgc = jnp.clip(rg, 0, rounds - 1)
            # input conveyor head: on row i this is round i*chunk + t, so
            # stage 0 reads exactly round t (its round for this tick)
            head = lax.dynamic_index_in_dim(queue, t % chunk, 0,
                                            keepdims=False)
            slot_in = jnp.where(i == 0, head, buf)
            # Double-buffered boundary slot: ``buf`` (the receive buffer,
            # carried from last tick) is only read here; each slot's
            # outgoing hop is issued immediately after its body produces
            # ``yw`` — a distinct send value, never aliasing ``buf`` — so
            # the collective-permute-start for slot w overlaps the bodies
            # of slots w+1.. instead of serializing behind the whole
            # tick's compute.
            ys, hops = [], []
            for w in range(width):
                pred = jnp.logical_and(
                    jnp.logical_and(active, owner[i, j, w]),
                    live[rgc * width + w])
                yw = lax.cond(
                    pred,
                    lambda x: step(i, p_here, x),
                    lambda x: jnp.zeros_like(x),
                    slot_in[w])
                ys.append(yw)
                if s_stages > 1:
                    # boundary activations: one slot-level hop down the
                    # pipe — the only other inter-stage traffic, exactly
                    # the DP's minimized quantity
                    hops.append(lax.ppermute(
                        yw, (stage_axis, replica_axis), perms[w]))
            y = jnp.stack(ys)
            # output conveyor: the last stage row injects its finished
            # round (inactive ticks injected zeros above); everyone else
            # passes along what arrived over the cyclic ring hop
            if s_stages > 1:
                incoming_out = lax.ppermute(transit, stage_axis,
                                            out_conveyor)
            else:
                incoming_out = transit
            arriving = jnp.where(i == s_stages - 1, y, incoming_out)
            # the round arriving at row i this tick (injected at tick
            # rg + S - 1, it reaches row i after (i + 1) mod S hops);
            # bank it here if output_bank_row — the single source of
            # truth shared with collect_staged_outputs — says so
            rg_o = t - (i + 1) % s_stages - (s_stages - 1)
            bank = jnp.logical_and(
                jnp.logical_and(rg_o >= 0, rg_o < rounds),
                output_bank_row(rg_o, rounds, s_stages) == i)
            deposited = lax.dynamic_update_index_in_dim(
                outq, arriving, jnp.clip(rg_o, 0, rounds - 1) // s_stages,
                0)
            outq = jnp.where(bank, deposited, outq)
            transit = arriving
            if s_stages > 1:
                # input conveyor: every row forwards its head one hop
                # toward stage 0 and banks the round from the row behind
                incoming = lax.ppermute(head, stage_axis, conveyor)
                queue = lax.dynamic_update_index_in_dim(
                    queue, incoming, t % chunk, 0)
                # next tick's receive buffer: the hops issued per slot
                # above (the send side of the double buffer)
                buf = jnp.stack(hops)
            return (buf, outq, transit, queue), None

        (_, outq, _, _), _ = lax.scan(tick, (buf0, outq0, transit0, queue0),
                                      jnp.arange(sched.n_ticks))
        return outq

    # each chip row banks only its ceil(rounds/S) conveyor-assigned rounds,
    # still replica-sharded (each replica banked only its owned slots,
    # zeros elsewhere) — collect_staged_outputs reassembles rounds and
    # sums the replica partials on the host instead of an inter-replica
    # all-reduce of the mostly-zero padded stream (the same zero-broadcast
    # this module's pipeline_forward fix removed)
    return _shard_map(per_device, mesh=mesh,
                      in_specs=(P(stage_axis), P(stage_axis)),
                      out_specs=P((stage_axis, replica_axis)),
                      check_vma=False)(stage_params, feed)


def replicated_forward(stage_fn, stage_params, microbatches: jax.Array,
                       mesh: Mesh, plan: StapPlan,
                       stage_axis: str = STAGE_AXIS,
                       replica_axis: str = REPLICA_AXIS) -> jax.Array:
    """Homogeneous replicated pipeline (the ``pipeline_forward``
    generalization): same-shape stages, microbatch m -> replica m % r_i.

    stage_fn(params_slice, x) -> y with y.shape == x.shape;
    stage_params leaves carry a leading stage dim; microbatches is
    (M, mb, ...) replicated. Returns the (M, mb, ...) last-stage outputs.
    """
    m = microbatches.shape[0]
    sched = staggered_schedule(plan, m)
    pad = sched.n_slots - m
    feed = jnp.pad(microbatches, ((0, pad),) + ((0, 0),) *
                   (microbatches.ndim - 1))
    feed = feed.reshape((sched.n_rounds, sched.round_width)
                        + microbatches.shape[1:])

    def step(_i, params_local, slot):
        return stage_fn(params_local, slot)

    staged = _round_executor(step, stage_params, feed, mesh, sched,
                             stage_axis=stage_axis,
                             replica_axis=replica_axis)
    outs = collect_staged_outputs(staged, sched)
    return outs.reshape((sched.n_slots,) + microbatches.shape[1:])[:m]


# --------------------------------------------------------------------------
# The span pipeline: heterogeneous Occam spans as switch-selected bodies
# --------------------------------------------------------------------------

def _payload_casts(policy):
    """(dequant, quant) boundary transforms for a policy: identity for
    None / the implicit fp32 policy; otherwise dequant lifts a payload
    into the policy's compute dtype at span entry and quant drops a span
    output back to the boundary dtype before it is packed for transport.
    """
    if policy is None or policy.is_default:
        ident = lambda arr: arr  # noqa: E731
        return ident, ident
    from repro.occam.quant import casting

    def dequant(q):
        return casting.dequantize(q, policy.boundary, policy.scale,
                                  compute=policy.compute)

    def quant(x):
        return casting.quantize(x, policy.boundary, policy.scale)

    return dequant, quant


def make_stage_body(net: NetSpec, stage: StageSpec, payload_width: int,
                    out_rows: int = 1, policy=None):
    """One stage's shard_map-traceable body: unflatten the span's
    parameter slice, unpack the boundary payload, run the span core the
    registry resolved for the route, and pack the outgoing payload
    (output map + spills + forwarded upstream sources).

    ``policy`` (an ``occam.quant.DtypePolicy``) makes the boundary
    genuinely quantized: the slot arrives in the boundary dtype,
    dequantizes at span entry (the span core computes in
    ``policy.compute``, always a float dtype), and the outgoing map /
    spills quantize back before packing. Forwarded upstream sources stay
    in their transport form — a map that rides several hops is quantized
    exactly once.

    Module-level because it is also a standalone jit target: the
    calibration timers (``repro.occam.calibrate.timers``) run each
    stage's body in isolation to measure per-stage wall-clock without a
    device mesh."""
    a, b = stage.span
    spec = registry.resolve_spmd_engine(stage.route.route)
    # per-stage effective tile height: a deep net's tail spans have
    # short output maps, so the planned out_rows clamps per span
    t = max(1, min(out_rows, net.map_shape(b)[0]))
    core = spec.make_spmd_body(net, a, b, stage.spill, stage.src_keys,
                               out_rows=t)
    dequant, quant = _payload_casts(policy)

    def body(p_flat, slot):
        span_params = _unflatten_span_params(p_flat, net, a, b)
        parts = _unpack(slot, stage.in_spec, net)
        x = dequant(parts[a])
        srcs = tuple(dequant(parts[s]) for s in stage.src_keys)
        out, spilled = core(span_params, x, srcs)
        out_parts = {}
        for s in stage.out_spec.keys:
            if s == b:
                out_parts[s] = quant(out)
            elif s in spilled:
                out_parts[s] = quant(spilled[s])
            elif s == a:
                # edge source == this span's input: forward the transport
                # form (already quantized), not the dequantized compute copy
                out_parts[s] = parts[s]
            else:
                out_parts[s] = parts[s]  # upstream source: forward it
        return _pack(out_parts, stage.out_spec, payload_width)

    return body


class _SpanProgram:
    """Shared static planning for the STAP executors: spans -> stages
    whose SPMD bodies dispatch through the engine registry
    (``EngineSpec.make_spmd_body``), flattened payload/parameter buffers,
    and the (stage, replica) mesh. :class:`StapPipeline` (fixed-round
    batch program) and :class:`StapRing` (single-tick serving step) both
    build on it."""

    def __init__(self, net: NetSpec,
                 partition: PartitionResult | Sequence[int],
                 microbatch: int = 1, *,
                 plan: StapPlan | None = None,
                 stage_times: Sequence[float] | None = None,
                 max_chips: int | None = None,
                 max_replicas: int | None = None,
                 target_period: float | None = None,
                 mesh: Mesh | None = None,
                 devices: Sequence | None = None,
                 routes: Sequence[span_engine.SpanRoute] | None = None,
                 out_rows: int = 1,
                 packing: str = "rect",
                 policy=None):
        if packing not in PACKINGS:
            raise ValueError(f"packing must be one of {PACKINGS}, "
                             f"got {packing!r}")
        # normalize the implicit fp32 policy to None so every downstream
        # hook has one no-quantization spelling
        if policy is not None and policy.is_default:
            policy = None
        self.policy = policy
        self.net = net
        self.boundaries = span_engine._boundaries_of(partition, net)
        self.stages = plan_span_stages(net, partition, routes=routes)
        n_stages = len(self.stages)
        self.microbatch = microbatch
        self.out_rows = out_rows
        self.packing = packing
        self.stage_times = tuple(stage_times) if stage_times is not None \
            else model_stage_times(net, self.stages)
        if plan is None:
            if packing == "sum":
                # sum packing exists to realize an *already chosen*
                # unbalanced replica vector on sum(replicas) chips; the
                # default planners reason in rectangular budgets
                raise ValueError("packing='sum' requires an explicit plan")
            plan = default_stap_plan(self.stage_times,
                                     target_period=target_period,
                                     max_chips=max_chips,
                                     max_replicas=max_replicas,
                                     mesh=mesh, devices=devices)
        if len(plan.replicas) != n_stages:
            raise ValueError(f"plan has {len(plan.replicas)} stages, "
                             f"partition has {n_stages}")
        self.plan = plan
        if packing == "sum":
            # lazy import: repro.occam's package init pulls this module in
            # via the deployment layer before occam.calibrate exists
            from repro.occam.calibrate.placement import pack_replicas
            self.assignment = pack_replicas(plan.replicas)
            if mesh is None:
                mesh = packed_mesh(self.assignment.n_chips, devices)
            elif mesh.shape.get(CHIP_AXIS) != self.assignment.n_chips:
                raise ValueError(
                    f"packed mesh is {CHIP_AXIS}="
                    f"{mesh.shape.get(CHIP_AXIS)} but the plan needs "
                    f"sum(replicas) = {self.assignment.n_chips} chips; "
                    f"build it with packed_mesh({self.assignment.n_chips})")
            self.mesh = mesh
        else:
            self.assignment = None
            self.mesh = mesh if mesh is not None else stap_mesh(
                n_stages, max(plan.replicas), devices)
        self.payload_width = max(max(st.in_spec.elems, st.out_spec.elems)
                                 for st in self.stages)
        self.param_width = max(
            (_span_param_elems(net, *st.span) for st in self.stages),
            default=1) or 1
        # the dtype every payload buffer (feed, ring state, ppermute
        # hops) is allocated and moved in — int8 boundaries really ship
        # a quarter of the fp32 bytes
        if self.policy is None:
            self._payload_dtype = jnp.float32
            self.payload_bytes_per_elem = 4.0
        else:
            from repro.occam.quant import casting
            self._payload_dtype = casting.jnp_dtype(self.policy.boundary)
            self.payload_bytes_per_elem = self.policy.boundary_bytes

    # -- static reporting ---------------------------------------------------

    @property
    def link_elems_per_image(self) -> int:
        """Boundary-payload elements moved per image: every interior
        boundary payload crosses its cut exactly once (per hop). This is
        the DP's minimized quantity; input delivery is accounted
        separately (:meth:`conveyor_elems_per_image`)."""
        return sum(st.out_spec.elems for st in self.stages[:-1])

    def executed_engine(self, stage: StageSpec) -> str:
        """The engine whose SPMD body the stage actually runs under
        shard_map, resolved through the registry: the route itself when it
        registered a ``make_spmd_body`` (pallas/scan/oracle all do —
        kernel-routed spans run the fused kernel, no scan substitution),
        else its declared ``spmd_fallback``."""
        return registry.resolve_spmd_engine(stage.route.route).name

    # -- SPMD program -------------------------------------------------------

    def _make_body(self, stage: StageSpec):
        return make_stage_body(self.net, stage, self.payload_width,
                               out_rows=self.out_rows, policy=self.policy)

    def _step(self):
        """step(stage_idx, p_flat, slot) -> slot' switching between the
        per-span bodies — only the selected branch executes at run time."""
        bodies = [self._make_body(st) for st in self.stages]

        def step(i_stage, p_flat, slot):
            return lax.switch(i_stage, bodies, p_flat, slot)

        return step

    def _param_rows(self) -> tuple[StageSpec, ...]:
        """One parameter row per mesh position: the stages themselves on
        the rectangular (stage, replica) mesh (replicas share a stage row
        via the replica axis), or per-chip stage copies on the packed
        chip axis (chip c holds exactly its assigned stage's span)."""
        if self.packing == "sum":
            return tuple(self.stages[i] for i in self.assignment.stage_ids())
        return self.stages

    def _stack_params(self, params: Sequence[dict]) -> jax.Array:
        # serving calls reuse the same weights; key the flatten/pad work on
        # the leaf buffers themselves (held by reference — an id() key
        # would go stale when the allocator recycles a freed array's
        # address) so steady-state run() skips it
        leaves = tuple(p[k] for p in params for k in sorted(p))
        cached = getattr(self, "_pstack_cache", None)
        if cached is not None and len(cached[0]) == len(leaves) and \
                all(a is b for a, b in zip(cached[0], leaves)):
            return cached[1]
        if self.policy is not None:
            from repro.occam.quant import casting
            params = casting.quantize_params(list(params), self.policy)
        stacked = jnp.stack([
            _flatten_span_params(params, self.net, *st.span,
                                 width=self.param_width)
            for st in self._param_rows()])
        self._pstack_cache = (leaves, stacked)
        return stacked


class StapPipeline(_SpanProgram):
    """A compiled STAP executor for one (net, partition, plan, batch) tuple.

    Build once, then ``run(params, xs)`` streams batches through the
    replicated span pipeline (the jit caches on the feed/param shapes, so
    repeated runs at one batch size pay no retrace). For mixed batch
    sizes from one compile, serve through :class:`StapRing`
    (``Deployment.serve``) instead.
    """

    def __init__(self, net: NetSpec,
                 partition: PartitionResult | Sequence[int],
                 batch: int, microbatch: int = 1, *,
                 plan: StapPlan | None = None,
                 stage_times: Sequence[float] | None = None,
                 max_chips: int | None = None,
                 max_replicas: int | None = None,
                 target_period: float | None = None,
                 mesh: Mesh | None = None,
                 devices: Sequence | None = None,
                 routes: Sequence[span_engine.SpanRoute] | None = None,
                 out_rows: int = 1, policy=None):
        super().__init__(net, partition, microbatch, plan=plan,
                         stage_times=stage_times, max_chips=max_chips,
                         max_replicas=max_replicas,
                         target_period=target_period, mesh=mesh,
                         devices=devices, routes=routes, out_rows=out_rows,
                         policy=policy)
        self.batch = batch
        self.n_microbatches = -(-batch // microbatch)
        self.schedule = staggered_schedule(self.plan, self.n_microbatches)
        self._fn = jax.jit(self._build())

    # -- static reporting ---------------------------------------------------

    @property
    def conveyor_elems_per_image(self) -> float:
        """Input-conveyor elements moved over stage links per image: each
        of the S-1 non-final rows forwards one (round_width, mb,
        payload_width) feed slot per tick, in every replica column (the
        queue is replicated over the replica axis; padding included — the
        ppermute moves the buffer regardless of content). This replaces
        the old whole-feed broadcast to every chip; on real hardware it
        is input streaming over ICI instead of S host-DRAM reads."""
        sched = self.schedule
        moved = (sched.n_ticks * (sched.n_stages - 1) * sched.max_replicas
                 * sched.round_width * self.microbatch * self.payload_width)
        return moved / self.batch

    @property
    def out_conveyor_elems_per_image(self) -> float:
        """Output-conveyor elements moved over stage links per image: the
        cyclic ring hop forwards every row's one-slot transit buffer each
        tick, in every replica column — the price of banking outputs at
        O(stream/S) per chip instead of every chip holding the full
        (rounds, width, slot) buffer."""
        sched = self.schedule
        if sched.n_stages == 1:
            return 0.0
        moved = (sched.n_ticks * sched.n_stages * sched.max_replicas
                 * sched.round_width * self.microbatch * self.payload_width)
        return moved / self.batch

    def report(self) -> dict:
        """Machine-readable run configuration (benchmarks / examples)."""
        return {
            "boundaries": list(self.boundaries),
            "spans": [list(st.span) for st in self.stages],
            "planned_routes": [st.route.route for st in self.stages],
            "engines": [self.executed_engine(st) for st in self.stages],
            "replicas": list(self.plan.replicas),
            "chips": self.plan.chips,
            "mesh_shape": [self.schedule.n_stages,
                           self.schedule.max_replicas],
            "round_width": self.schedule.round_width,
            "n_rounds": self.schedule.n_rounds,
            "n_ticks": self.schedule.n_ticks,
            "microbatch": self.microbatch,
            "n_microbatches": self.n_microbatches,
            "payload_elems": [st.out_spec.elems for st in self.stages[:-1]],
            "payload_width_padded": self.payload_width,
            "link_elems_per_image": self.link_elems_per_image,
            "conveyor_elems_per_image": self.conveyor_elems_per_image,
            "out_conveyor_elems_per_image": self.out_conveyor_elems_per_image,
            "dp_transfer_elems_per_image": cnn.predicted_transfers(
                self.net, list(self.boundaries)),
            # byte-denominated twins: the same quantities in the bytes
            # the wire actually carries (payloads move in the policy's
            # boundary dtype — 4.0 B/elem for the implicit fp32 policy)
            "payload_bytes_per_elem": self.payload_bytes_per_elem,
            "link_bytes_per_image":
                self.link_elems_per_image * self.payload_bytes_per_elem,
            "conveyor_bytes_per_image":
                self.conveyor_elems_per_image * self.payload_bytes_per_elem,
            "out_conveyor_bytes_per_image":
                self.out_conveyor_elems_per_image
                * self.payload_bytes_per_elem,
        }

    # -- SPMD program -------------------------------------------------------

    def _build(self):
        step = self._step()
        sched, mesh = self.schedule, self.mesh

        def fn(params_stacked, feed):
            return _round_executor(step, params_stacked, feed, mesh, sched)

        return fn

    # -- data movement ------------------------------------------------------

    def _pack_feed(self, xs: jax.Array) -> jax.Array:
        """Flatten + pad the stream, staged for the input conveyor: the
        rounds dimension is padded to S * chunk so ``run`` can shard it
        over the stage axis (chip row i holds rounds [i*chunk,
        (i+1)*chunk)) instead of replicating the whole feed to every
        device — per-chip input memory O(stream/S)."""
        mb, m = self.microbatch, self.n_microbatches
        if self.policy is not None:
            from repro.occam.quant import casting
            xs = casting.quantize(xs, self.policy.boundary,
                                  self.policy.scale)
        xs = jnp.pad(xs, ((0, m * mb - xs.shape[0]),) + ((0, 0),) * 3)
        flat = xs.reshape(m, mb, -1)
        flat = jnp.pad(flat, ((0, self.schedule.n_slots - m), (0, 0),
                              (0, self.payload_width - flat.shape[-1])))
        feed = flat.reshape(self.schedule.n_rounds,
                            self.schedule.round_width, mb,
                            self.payload_width)
        return stage_feed(feed, self.schedule.n_stages)

    def _stage_feed_sharding(self) -> jax.sharding.NamedSharding:
        """Rounds sharded over the stage axis, replicated over replicas."""
        return jax.sharding.NamedSharding(self.mesh, P(STAGE_AXIS))

    def run(self, params: Sequence[dict], xs: jax.Array,
            counter: cnn.TrafficCounter | None = None) -> jax.Array:
        """Stream ``xs`` ((B, H, W, C)) through the pipeline -> (B, ...).

        ``counter`` accumulates the model's off-chip transfers with the
        same engine-independent accounting as ``span_engine``
        (model == machine: totals equal ``predicted_transfers`` x batch).
        """
        if xs.ndim != 4:
            raise ValueError("stap pipeline streams batched (B, H, W, C)")
        if xs.shape[0] != self.batch:
            raise ValueError(f"pipeline compiled for batch {self.batch}, "
                             f"got {xs.shape[0]}")
        bpe = self.payload_bytes_per_elem
        for st in self.stages:
            a, b = st.span
            cnn.count_span_reads(counter, self.net, a, b, self.batch,
                                 bytes_per_elem=bpe)
            cnn.count_span_writes(counter, self.net, b, st.spill, self.batch,
                                  bytes_per_elem=bpe)
        # stage the input onto the mesh up front: each chip row receives
        # only its conveyor chunk of rounds (no whole-feed replication)
        feed = jax.device_put(self._pack_feed(xs), self._stage_feed_sharding())
        staged = self._fn(self._stack_params(params), feed)
        # the executable's output is conveyor-banked (each chip row holds
        # ceil(rounds/S) rounds); reassembly happens here, off the chips
        out = collect_staged_outputs(staged, self.schedule)
        h, w, c = self.net.map_shape(self.net.n_layers)
        flat = out.reshape(self.schedule.n_slots, self.microbatch,
                           self.payload_width)[:self.n_microbatches]
        y = flat[:, :, :h * w * c].reshape(-1, h, w, c)
        if self.policy is not None:
            # the last boundary crossed in the boundary dtype; hand the
            # caller fp32 images (replica-partial summation may have
            # widened an integer dtype — dequantize handles either form)
            from repro.occam.quant import casting
            y = casting.dequantize(y, self.policy.boundary,
                                   self.policy.scale)
        return y[:self.batch]


class StapRing(_SpanProgram):
    """The serving form of the STAP pipeline: ONE compiled fixed-shape
    SPMD tick, iterated host-side over an unbounded stream.

    Where :class:`StapPipeline` lowers a whole fixed-round program per
    stream length (the round count is baked into its ``lax.scan``), the
    ring compiles a single round-width tick: stage i serves the round
    that entered i ticks ago, then every slot's boundary payload hops one
    stage down the pipe — the carried *ring state*, one pending round per
    stage (``ring_depth`` rounds in flight). Every tick's shapes are
    fixed by (round_width, microbatch, payload_width), so one lowering
    serves every submit size; ragged traffic is packed into fixed rounds
    by ``repro.occam.Session`` with a per-stage slot-validity mask
    (masked slots skip their span body via ``lax.cond`` and are excluded
    from outputs and measured traffic by the session).

    Per-chip buffers are O(round_batch), independent of stream length:
    the tick consumes one round, holds one round of ring state, and
    emits one round — the serving limit of the batch pipeline's
    input/output conveyors.
    """

    def __init__(self, net: NetSpec,
                 partition: PartitionResult | Sequence[int],
                 microbatch: int = 1, *,
                 plan: StapPlan,
                 mesh: Mesh | None = None,
                 devices: Sequence | None = None,
                 routes: Sequence[span_engine.SpanRoute] | None = None,
                 out_rows: int = 1,
                 packing: str = "rect",
                 policy=None):
        super().__init__(net, partition, microbatch, plan=plan, mesh=mesh,
                         devices=devices, routes=routes, out_rows=out_rows,
                         packing=packing, policy=policy)
        self.steady = steady_schedule(self.plan)
        self.trace_count = 0   # tick lowerings; regression: stays at 1
        tick = self._build_tick_packed() if self.packing == "sum" \
            else self._build_tick()
        self._tick = jax.jit(tick)
        # windowed tick dispatch timer (occam.calibrate observability);
        # under steady load dispatch wall time converges to the device
        # tick time via XLA's dispatch backpressure
        from repro.occam.calibrate.timers import TickTimers
        self.timers = TickTimers()

    # -- geometry -----------------------------------------------------------

    @property
    def round_width(self) -> int:
        return self.steady.round_width

    @property
    def ring_depth(self) -> int:
        """Rounds in flight (= stages): submit-to-result latency in ticks."""
        return self.steady.ring_depth

    @property
    def round_batch(self) -> int:
        """Images per serving round: round_width slots x microbatch."""
        return self.steady.round_width * self.microbatch

    def report(self) -> dict:
        """Machine-readable serving configuration."""
        return {
            "boundaries": list(self.boundaries),
            "spans": [list(st.span) for st in self.stages],
            "planned_routes": [st.route.route for st in self.stages],
            "engines": [self.executed_engine(st) for st in self.stages],
            "replicas": list(self.plan.replicas),
            "chips": self.plan.chips,
            "packing": self.packing,
            "mesh_shape": ([self.assignment.n_chips]
                           if self.packing == "sum" else
                           [self.steady.n_stages, self.steady.max_replicas]),
            "round_width": self.round_width,
            "round_batch": self.round_batch,
            "ring_depth": self.ring_depth,
            "microbatch": self.microbatch,
            "payload_width_padded": self.payload_width,
            "link_elems_per_image": self.link_elems_per_image,
            "payload_bytes_per_elem": self.payload_bytes_per_elem,
            "link_bytes_per_image":
                self.link_elems_per_image * self.payload_bytes_per_elem,
            "tick_lowerings": self.trace_count,
            "tick_count": self.timers.count,
            "tick_mean_s": self.timers.mean_s(),
            "tick_busy_fraction": self.timers.busy_fraction(),
        }

    # -- SPMD tick ----------------------------------------------------------

    def init_state(self) -> jax.Array:
        """A zeroed ring: each stage's pending-round payload slots,
        sharded over the (stage, replica) mesh — or over the flat chip
        axis under sum packing. Shape is fixed by the geometry —
        O(round_batch) per chip, stream-independent."""
        if self.packing == "sum":
            state = jnp.zeros((self.assignment.n_chips * self.round_width,
                               self.microbatch, self.payload_width),
                              self._payload_dtype)
            return jax.device_put(state, jax.sharding.NamedSharding(
                self.mesh, P(CHIP_AXIS)))
        s, r = self.steady.n_stages, self.steady.max_replicas
        state = jnp.zeros((s * r * self.round_width, self.microbatch,
                           self.payload_width), self._payload_dtype)
        return jax.device_put(state, jax.sharding.NamedSharding(
            self.mesh, P((STAGE_AXIS, REPLICA_AXIS))))

    def _build_tick(self):
        step = self._step()
        steady, mesh = self.steady, self.mesh
        s_stages, width = steady.n_stages, steady.round_width
        owner = jnp.asarray(np.array(steady.owner_table()))     # (S, R, W)
        perms = [steady.slot_perm(w) for w in range(width)]

        def per_device(params_local, state, in_round, masks):
            i = lax.axis_index(STAGE_AXIS)
            j = lax.axis_index(REPLICA_AXIS)
            p_here = jax.tree.map(lambda l: l[0], params_local)
            slot_in = jnp.where(i == 0, in_round, state)
            # Double-buffered boundary slot (as in ``_round_executor``):
            # ``state`` is the receive buffer, read-only this tick; each
            # slot's hop is issued right after its body so the transfer
            # overlaps the remaining slots' compute.
            ys, hops = [], []
            for w in range(width):
                # masks[i] is the validity of the round at stage i (the
                # session tracks what entered i ticks ago); a masked slot
                # skips its span body entirely
                pred = jnp.logical_and(owner[i, j, w], masks[i, w])
                yw = lax.cond(
                    pred,
                    lambda x: step(i, p_here, x),
                    lambda x: jnp.zeros_like(x),
                    slot_in[w])
                ys.append(yw)
                if s_stages > 1:
                    # boundary payloads hop one stage down the pipe — the
                    # ring state carried to the next tick
                    hops.append(lax.ppermute(
                        yw, (STAGE_AXIS, REPLICA_AXIS), perms[w]))
            y = jnp.stack(ys)
            out = jnp.where(i == s_stages - 1, y, jnp.zeros_like(y))
            state = jnp.stack(hops) if s_stages > 1 else jnp.zeros_like(y)
            return state, out

        mapped = _shard_map(per_device, mesh=mesh,
                            in_specs=(P(STAGE_AXIS),
                                      P((STAGE_AXIS, REPLICA_AXIS)),
                                      P(), P()),
                            out_specs=(P((STAGE_AXIS, REPLICA_AXIS)),
                                       P((STAGE_AXIS, REPLICA_AXIS))),
                            check_vma=False)
        r_max, mb = steady.max_replicas, self.microbatch
        h, w, c = self.net.map_shape(self.net.n_layers)
        out_cast = self._lane_cast()

        def fn(params_stacked, state, in_round, masks):
            # trace-time side effect: one increment per lowering, the
            # one-compile-across-submit-sizes regression signal
            self.trace_count += 1
            state, out = mapped(params_stacked, state, in_round, masks)
            # collect the exiting round inside the same dispatch: last
            # stage row only, replica partials summed (still never an
            # inter-replica all-reduce of the whole stream — this is one
            # round), payload lanes cut down to output images
            out = out[(s_stages - 1) * r_max * width:]
            out = out.reshape((r_max, width * mb, self.payload_width)) \
                .sum(axis=0)
            lanes = out_cast(out[:, :h * w * c].reshape(-1, h, w, c))
            return state, lanes

        return fn

    def _lane_cast(self):
        """Exit transform for the round leaving the last stage: the
        payload crossed in the boundary dtype (replica-partial summation
        may have widened an integer form); sessions get fp32 images."""
        if self.policy is None:
            return lambda lanes: lanes
        from repro.occam.quant import casting
        pol = self.policy
        return lambda lanes: casting.dequantize(lanes, pol.boundary,
                                                pol.scale)

    def _build_tick_packed(self):
        """The sum-of-replicas tick: same ring semantics as
        :meth:`_build_tick`, lowered over a flat ``sum(replicas)``-chip
        mesh instead of the rectangular (stage, replica) grid. Each chip
        knows its stage from the static :class:`ChipAssignment` tables;
        slot ownership and the per-slot boundary hops route over flat
        chip ids, so an unbalanced 4-3-2 plan really occupies 9 devices
        (paper §III-E) with no padded idle replicas."""
        step = self._step()
        steady, mesh, asg = self.steady, self.mesh, self.assignment
        s_stages, width = steady.n_stages, steady.round_width
        stage_ids = jnp.asarray(np.array(asg.stage_ids()))       # (C,)
        owner = jnp.asarray(np.array(asg.owner_table(steady)))   # (C, W)
        perms = [asg.slot_perm(steady, w) for w in range(width)]

        def per_device(params_local, state, in_round, masks):
            c = lax.axis_index(CHIP_AXIS)
            i = stage_ids[c]
            p_here = jax.tree.map(lambda l: l[0], params_local)
            slot_in = jnp.where(i == 0, in_round, state)
            # Double-buffered boundary slot (as in the rect tick): the
            # carried ``state`` is read-only this tick; each slot's hop
            # is issued right after its body.
            ys, hops = [], []
            for w in range(width):
                pred = jnp.logical_and(owner[c, w], masks[i, w])
                yw = lax.cond(
                    pred,
                    lambda x: step(i, p_here, x),
                    lambda x: jnp.zeros_like(x),
                    slot_in[w])
                ys.append(yw)
                if s_stages > 1:
                    hops.append(lax.ppermute(yw, CHIP_AXIS, perms[w]))
            y = jnp.stack(ys)
            out = jnp.where(i == s_stages - 1, y, jnp.zeros_like(y))
            state = jnp.stack(hops) if s_stages > 1 else jnp.zeros_like(y)
            return state, out

        mapped = _shard_map(per_device, mesh=mesh,
                            in_specs=(P(CHIP_AXIS), P(CHIP_AXIS), P(), P()),
                            out_specs=(P(CHIP_AXIS), P(CHIP_AXIS)),
                            check_vma=False)
        mb = self.microbatch
        h, w, c = self.net.map_shape(self.net.n_layers)
        last0 = asg.offsets[s_stages - 1]       # first last-stage chip
        r_last = asg.replicas[s_stages - 1]
        out_cast = self._lane_cast()

        def fn(params_stacked, state, in_round, masks):
            self.trace_count += 1
            state, out = mapped(params_stacked, state, in_round, masks)
            # collect the exiting round: last-stage chips only, replica
            # partials summed (each served only its owned slots)
            out = out[last0 * width:]
            out = out.reshape((r_last, width * mb, self.payload_width)) \
                .sum(axis=0)
            lanes = out_cast(out[:, :h * w * c].reshape(-1, h, w, c))
            return state, lanes

        return fn

    def tick(self, params: Sequence[dict], state: jax.Array,
             in_round: jax.Array, masks) -> tuple[jax.Array, jax.Array]:
        """Advance the ring one tick.

        ``in_round``: (round_width, mb, payload_width) packed round
        entering stage 0 (see :meth:`pack_round`). ``masks``: (S, W) bool
        — slot validity of the round resident at each stage this tick.
        Returns ``(state', lanes)`` where ``lanes`` (round_batch, h, w, c)
        is the round leaving the last stage (the one submitted
        ``ring_depth - 1`` ticks ago; replica partials combined inside
        the tick's dispatch — one round, never an all-reduce of a
        stream-sized buffer).
        """
        with self.timers.time():
            return self._tick(self._stack_params(params), state,
                              jnp.asarray(in_round),
                              jnp.asarray(masks, dtype=bool))

    # -- data movement ------------------------------------------------------

    def pack_round(self, xs: jax.Array) -> jax.Array:
        """(n <= round_batch, H, W, C) images -> (W, mb, payload_width)
        flat round, zero-padded on trailing lanes (mask them)."""
        xs = jnp.asarray(xs)
        pad = self.round_batch - xs.shape[0]
        if pad < 0:
            raise ValueError(f"round takes at most {self.round_batch} "
                             f"images, got {xs.shape[0]}")
        if self.policy is not None:
            from repro.occam.quant import casting
            xs = casting.quantize(xs, self.policy.boundary,
                                  self.policy.scale)
        xs = jnp.pad(xs, ((0, pad),) + ((0, 0),) * 3)
        flat = xs.reshape(self.round_width, self.microbatch, -1)
        return jnp.pad(flat, ((0, 0), (0, 0),
                              (0, self.payload_width - flat.shape[-1])))



def stream(params: Sequence[dict], xs: jax.Array, net: NetSpec,
           partition: PartitionResult | Sequence[int], *,
           microbatch: int = 1, plan: StapPlan | None = None,
           stage_times: Sequence[float] | None = None,
           max_chips: int | None = None, max_replicas: int | None = None,
           target_period: float | None = None,
           mesh: Mesh | None = None, devices: Sequence | None = None,
           counter: cnn.TrafficCounter | None = None
           ) -> tuple[jax.Array, StapPipeline]:
    """One-shot convenience wrapper: build the pipeline and stream ``xs``.

    Returns ``(y, pipeline)`` — keep the pipeline object to stream more
    batches without retracing, or read ``pipeline.report()``.
    """
    pipe = StapPipeline(net, partition, xs.shape[0], microbatch, plan=plan,
                        stage_times=stage_times, max_chips=max_chips,
                        max_replicas=max_replicas,
                        target_period=target_period, mesh=mesh,
                        devices=devices)
    return pipe.run(params, xs, counter=counter), pipe
