"""Deterministic synthetic data pipeline with background prefetch.

Tokens follow a learnable hidden-permutation process: token t+1 is
``perm[token t]`` with probability (1 - noise), else uniform — so a real
model's loss drops quickly below log(V) (used by the end-to-end example and
convergence tests), while remaining fully deterministic per (seed, step,
shard) for failure-recovery replay: after a restart at step k, batch k is
bit-identical (no data loss / duplication — the checkpoint stores only the
step counter).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    n_shards: int = 1
    shard: int = 0

    def __post_init__(self) -> None:
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide across shards")
        rng = np.random.default_rng(self.seed)
        self.perm = rng.permutation(self.vocab)

    @property
    def shard_batch(self) -> int:
        return self.global_batch // self.n_shards

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for (step, shard): {tokens, labels}."""
        rng = np.random.default_rng(
            (self.seed, step, self.shard, 0xD00D) if self.seed is not None
            else step)
        b, s = self.shard_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        flip = rng.random((b, s)) < self.noise
        rand = rng.integers(0, self.vocab, size=(b, s))
        for t in range(s):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(flip[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator (depth-bounded)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker() -> None:
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
